"""Serving path: prefill == forward, decode == incremental forward."""
import pytest

from helpers import run_multidevice

ARCHS = ["qwen3-8b", "gemma3-1b", "mixtral-8x22b", "deepseek-v3-671b",
         "mamba2-130m", "zamba2-7b", "seamless-m4t-large-v2",
         "phi-3-vision-4.2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch):
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup, input_specs as isp
from repro.models import transformer
from repro.train import serve as serve_mod

ARCH = {arch!r}
cfg = dataclasses.replace(get_smoke_config(ARCH), dtype=jnp.float32)
comm = CommConfig()
mesh = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh, comm, concrete=True)
rng = np.random.RandomState(0)
B, S = 4, 32
shape = isp.ShapeSpec("smoke", S, B, "prefill")
rt, pre_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape)
batch = {{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}}
if cfg.family == "vlm":
    batch["patches"] = jnp.asarray(
        rng.randn(B, cfg.num_patches, cfg.frontend_dim), jnp.float32)
if cfg.family == "audio":
    batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.frontend_dim), jnp.float32)
state = pre_fn(sess.params, batch)
vocab_sharded = cfg.vocab_size % 4 == 0
fwd = jax.jit(compat.shard_map(
    lambda p, b: transformer.forward(p, b, rt, train=False).logits,
    mesh=mesh,
    in_specs=(sess.param_spec, jax.tree.map(lambda _: P(("data",)), batch)),
    out_specs=P(("data",), None, "model" if vocab_sharded else None),
    check_vma=False))
full = np.asarray(fwd(sess.params, batch))
pre = np.asarray(state.last_logits)
err = np.abs(full[:, -1] - pre).max() / (np.abs(full[:, -1]).max() + 1e-9)
assert err < 2e-3, err
print("PREFILL OK", err)
""".format(arch=arch))
    assert "PREFILL OK" in out


def test_decode_matches_extended_prefill():
    """Greedy-decoding N tokens == prefilling the extended sequence."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup, input_specs as isp
from repro.train import serve as serve_mod

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
comm = CommConfig()
mesh = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh, comm, concrete=True)
rng = np.random.RandomState(0)
B, S, GEN = 4, 24, 4
MAX = S + GEN
shape_p = isp.ShapeSpec("s", MAX, B, "prefill")
shape_d = isp.ShapeSpec("s", MAX, B, "decode")
_, pre_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_p)
_, dec_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_d)

tokens = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
# NOTE: prefill pads its cache to MAX via cache capacity = shape seq len;
# pass the PROMPT at its own length
state = pre_fn(sess.params, {"tokens": jnp.asarray(tokens)})
seq = tokens.copy()
for i in range(GEN):
    nxt = np.asarray(jnp.argmax(state.last_logits, axis=-1)).astype(np.int32)
    seq = np.concatenate([seq, nxt[:, None]], axis=1)
    state = dec_fn(sess.params, jnp.asarray(nxt), state)

# reference: prefill the full generated sequence; logits at each step must
# produce the same greedy choices
ref_state = pre_fn(sess.params, {"tokens": jnp.asarray(
    np.pad(seq[:, :MAX], ((0, 0), (0, max(0, MAX - seq.shape[1])))))})
last_dec = np.asarray(jnp.argmax(state.last_logits, -1))
last_ref = np.asarray(jnp.argmax(ref_state.last_logits, -1))
assert np.array_equal(last_dec, last_ref), (last_dec, last_ref)
print("DECODE OK")
""")
    assert "DECODE OK" in out
