"""Serving path: prefill == forward, decode == incremental forward."""
import pytest

from helpers import run_multidevice

ARCHS = ["qwen3-8b", "gemma3-1b", "mixtral-8x22b", "deepseek-v3-671b",
         "mamba2-130m", "zamba2-7b", "seamless-m4t-large-v2",
         "phi-3-vision-4.2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch):
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup, input_specs as isp
from repro.models import transformer
from repro.train import serve as serve_mod

ARCH = {arch!r}
cfg = dataclasses.replace(get_smoke_config(ARCH), dtype=jnp.float32)
comm = CommConfig()
mesh = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh, comm, concrete=True)
rng = np.random.RandomState(0)
B, S = 4, 32
shape = isp.ShapeSpec("smoke", S, B, "prefill")
rt, pre_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape)
batch = {{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}}
if cfg.family == "vlm":
    batch["patches"] = jnp.asarray(
        rng.randn(B, cfg.num_patches, cfg.frontend_dim), jnp.float32)
if cfg.family == "audio":
    batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.frontend_dim), jnp.float32)
state = pre_fn(sess.params, batch)
vocab_sharded = cfg.vocab_size % 4 == 0
fwd = jax.jit(compat.shard_map(
    lambda p, b: transformer.forward(p, b, rt, train=False).logits,
    mesh=mesh,
    in_specs=(sess.param_spec, jax.tree.map(lambda _: P(("data",)), batch)),
    out_specs=P(("data",), None, "model" if vocab_sharded else None),
    check_vma=False))
full = np.asarray(fwd(sess.params, batch))
pre = np.asarray(state.last_logits)
err = np.abs(full[:, -1] - pre).max() / (np.abs(full[:, -1]).max() + 1e-9)
assert err < 2e-3, err
print("PREFILL OK", err)
""".format(arch=arch))
    assert "PREFILL OK" in out


def test_decode_matches_extended_prefill():
    """Greedy-decoding N tokens == prefilling the extended sequence."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup, input_specs as isp
from repro.train import serve as serve_mod

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
comm = CommConfig()
mesh = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh, comm, concrete=True)
rng = np.random.RandomState(0)
B, S, GEN = 4, 24, 4
MAX = S + GEN
shape_p = isp.ShapeSpec("s", S, B, "prefill")
shape_d = isp.ShapeSpec("s", MAX, B, "decode")
# Prefill spec at the PROMPT length; its caches cover MAX via cache_capacity.
_, pre_fn, pre_abs = serve_mod.build_serve_fn(
    cfg, mesh, comm, shape_p, cache_capacity=serve_mod.cache_len(cfg, shape_d))
_, dec_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_d)
assert pre_abs[1]["tokens"].shape == (B, S), pre_abs[1]["tokens"].shape

tokens = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
state = pre_fn(sess.params, {"tokens": jnp.asarray(tokens)})
seq = tokens.copy()
for i in range(GEN):
    nxt = np.asarray(jnp.argmax(state.last_logits, axis=-1)).astype(np.int32)
    seq = np.concatenate([seq, nxt[:, None]], axis=1)
    state = dec_fn(sess.params, jnp.asarray(nxt), state)

# reference: prefill the full generated sequence; logits at each step must
# produce the same greedy choices
shape_ref = isp.ShapeSpec("s", MAX, B, "prefill")
_, ref_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_ref)
ref_state = ref_fn(sess.params, {"tokens": jnp.asarray(seq[:, :MAX])})
last_dec = np.asarray(jnp.argmax(state.last_logits, -1))
last_ref = np.asarray(jnp.argmax(ref_state.last_logits, -1))
assert np.array_equal(last_dec, last_ref), (last_dec, last_ref)
print("DECODE OK")
""")
    assert "DECODE OK" in out


def test_prefill_spec_at_prompt_length():
    """Satellite regression: the prefill builder's spec is built at the
    prompt's own sequence length (the traced program matches what is fed)
    while ``cache_capacity`` independently sizes the KV caches for the
    planned generation — and the builders reject the nonsense combinations
    (capacity smaller than the prompt, capacity on the decode builder)."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup, input_specs as isp
from repro.train import serve as serve_mod

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
comm = CommConfig()
mesh = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh, comm, concrete=True)
rng = np.random.RandomState(0)
B, S, MAX = 4, 12, 24
shape_p = isp.ShapeSpec("s", S, B, "prefill")
shape_d = isp.ShapeSpec("s", MAX, B, "decode")
rt, pre_fn, (params_abs, batch_abs) = serve_mod.build_serve_fn(
    cfg, mesh, comm, shape_p, cache_capacity=MAX)
assert batch_abs["tokens"].shape == (B, S), batch_abs["tokens"].shape

state = pre_fn(sess.params, {"tokens": jnp.asarray(
    rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32))})
# Caches were sized by cache_capacity, not the prompt: a full MAX - S
# generation fits without re-prefilling.
_, dec_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_d)
for _ in range(MAX - S):
    nxt = jnp.argmax(state.last_logits, axis=-1).astype(jnp.int32)
    state = dec_fn(sess.params, nxt, state)
assert state.last_logits.shape[0] == B

# Defaulted capacity == prompt length (a cache exactly as long as fed).
_, _, (_, small_abs) = serve_mod.build_serve_fn(cfg, mesh, comm, shape_p)
assert small_abs["tokens"].shape == (B, S)

try:
    serve_mod.build_serve_fn(cfg, mesh, comm, shape_p, cache_capacity=S - 1)
    raise AssertionError("capacity < prompt must raise")
except ValueError:
    pass
try:
    serve_mod.build_serve_fn(cfg, mesh, comm, shape_d, cache_capacity=MAX)
    raise AssertionError("cache_capacity on the decode builder must raise")
except ValueError:
    pass
print("PROMPT SPEC OK")
""")
    assert "PROMPT SPEC OK" in out


def test_auto_comm_selects_per_phase():
    """comm="auto": prefill and decode resolve DIFFERENT CommConfigs from
    one engineered TuneDB (consumer-tagged entries), and decode under the
    auto-resolved config is bitwise-identical to passing that config
    statically."""
    out = run_multidevice("""
import dataclasses, tempfile, os
import jax, numpy as np, jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig, CommMode, Scheduling, Transport
from repro.launch import setup, input_specs as isp
from repro.train import serve as serve_mod
from repro.tune.db import TuneDB, TuneEntry, topology_key
from repro.tune.space import config_to_dict

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
topo = topology_key(mesh)

# Engineered DB: the decode_step loop says the small-chunk overlapped
# config wins, the prefill loop says the jumbo fused config does.
A = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.OVERLAPPED,
               transport=Transport.UNORDERED, window=4, chunk_bytes=4096)
Bc = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.FUSED,
                transport=Transport.UNORDERED, window=8, chunk_bytes=1 << 20)
db = TuneDB()
for consumer, msg, win, lose in (("decode_step", 4096, A, Bc),
                                 ("prefill", 1 << 20, Bc, A)):
    db.add(TuneEntry(topo=topo, collective="all_reduce", msg_bytes=msg,
                     config=config_to_dict(win), us_per_call=10.0,
                     e2e_us=20.0, consumer=consumer))
    db.add(TuneEntry(topo=topo, collective="all_reduce", msg_bytes=msg,
                     config=config_to_dict(lose), us_per_call=9.0,
                     e2e_us=60.0, consumer=consumer))
with tempfile.TemporaryDirectory() as td:
    db_path = os.path.join(td, "tunedb.json")
    db.save(db_path)

    B, S, MAX = 4, 12, 16
    shape_p = isp.ShapeSpec("s", S, B, "prefill")
    shape_d = isp.ShapeSpec("s", MAX, B, "decode")
    rt_p, pre_fn, _ = serve_mod.build_serve_fn(
        cfg, mesh, "auto", shape_p, tune_db_path=db_path,
        cache_capacity=MAX)
    rt_d, dec_fn, _ = serve_mod.build_serve_fn(
        cfg, mesh, "auto", shape_d, tune_db_path=db_path)
    assert rt_p.comm == Bc, rt_p.comm
    assert rt_d.comm == A, rt_d.comm
    assert rt_p.comm != rt_d.comm

    # Decode under auto == decode under the explicit winning config, bitwise.
    sess = setup.build_session(cfg, mesh, CommConfig(), concrete=True)
    _, dec_static, _ = serve_mod.build_serve_fn(cfg, mesh, A, shape_d)
    tokens = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (B, S)).astype(np.int32)
    s_auto = pre_fn(sess.params, {"tokens": jnp.asarray(tokens)})
    s_stat = s_auto
    for _ in range(MAX - S):
        nxt = jnp.argmax(s_auto.last_logits, axis=-1).astype(jnp.int32)
        s_auto = dec_fn(sess.params, nxt, s_auto)
        s_stat = dec_static(sess.params, nxt, s_stat)
        np.testing.assert_array_equal(np.asarray(s_auto.last_logits),
                                      np.asarray(s_stat.last_logits))
print("AUTO PHASE OK")
""")
    assert "AUTO PHASE OK" in out
