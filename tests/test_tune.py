"""Autotuner subsystem: search space, TuneDB, calibration, selection, and
the latmodel regressions the tuner's cost model depends on."""
import dataclasses
import itertools
import json

import numpy as np
import pytest

from helpers import run_multidevice


# ----------------------------------------------------------------------
# Search space
# ----------------------------------------------------------------------

def test_search_space_pruning_matches_commconfig_validation():
    """enumerate_configs must contain exactly the combos CommConfig accepts
    (after canonicalizing fields the collective never reads)."""
    from repro.core.config import CommConfig
    from repro.tune.space import DEFAULT_AXES, enumerate_configs, space_size

    names = list(DEFAULT_AXES)
    valid, invalid = set(), 0
    for combo in itertools.product(*(DEFAULT_AXES[n] for n in names)):
        try:
            valid.add(CommConfig(**dict(zip(names, combo))))
        except ValueError:
            invalid += 1
    assert invalid > 0, "the axes should include invalid combos to prune"
    assert valid, "the axes should include valid combos"

    # No collective filter: enumeration = validation minus window-dedup.
    enumerated = set(enumerate_configs(collective=None))
    assert enumerated <= valid
    for cfg in enumerated:
        CommConfig(**dataclasses.asdict(cfg))   # re-validates
    # The unordered-transport window dedup is the only collapse applied.
    from repro.core.config import Transport
    collapsed = {dataclasses.replace(c, window=CommConfig().window)
                 if c.transport == Transport.UNORDERED else c for c in valid}
    assert enumerated == collapsed
    assert len(enumerated) < space_size()


def test_search_space_collective_canonicalization():
    from repro.tune.space import enumerate_configs
    # sendrecv never reads algorithm/compression -> all candidates share the
    # defaults for those fields, and the space is strictly smaller.
    p2p = enumerate_configs("sendrecv")
    assert all(c.algorithm == "native" for c in p2p)
    assert len(p2p) < len(enumerate_configs("all_reduce"))


def test_config_dict_roundtrip():
    from repro.tune.space import (config_from_dict, config_to_dict,
                                  enumerate_configs)
    for cfg in enumerate_configs("all_reduce"):
        wire = json.loads(json.dumps(config_to_dict(cfg)))
        assert config_from_dict(wire) == cfg


# ----------------------------------------------------------------------
# TuneDB
# ----------------------------------------------------------------------

def _entry(msg_bytes, us, topo="cpu:8", coll="all_reduce", hops=1,
           e2e_us=0.0, **cfg_kw):
    from repro.core.config import CommConfig
    from repro.tune.db import TuneEntry
    from repro.tune.space import config_to_dict
    return TuneEntry(topo=topo, collective=coll, msg_bytes=msg_bytes,
                     config=config_to_dict(CommConfig(**cfg_kw)),
                     us_per_call=us, gbps=msg_bytes / us / 1e3, hops=hops,
                     e2e_us=e2e_us)


def test_tunedb_roundtrip_and_nearest(tmp_path):
    from repro.tune.db import TuneDB
    db = TuneDB()
    db.add(_entry(1024, 50.0))
    db.add(_entry(1024, 20.0, window=8))          # faster config, same key
    db.add(_entry(1 << 20, 900.0))
    path = tmp_path / "tunedb.json"
    db.save(path)
    back = TuneDB.load(path)
    assert len(back) == len(db) == 3

    assert back.best("all_reduce", 1024, "cpu:8").us_per_call == 20.0
    # nearest in LOG space: 16 KiB is closer to 1 KiB than to 1 MiB
    near = back.nearest("all_reduce", 16 << 10, "cpu:8")
    assert near.msg_bytes == 1024 and near.us_per_call == 20.0
    assert back.nearest("all_reduce", 700 << 10, "cpu:8").msg_bytes == 1 << 20
    # unknown collective / topo -> None
    assert back.best("all_to_all", 1024, "cpu:8") is None
    assert back.nearest("all_reduce", 1024, "tpu:64") is None


def test_tunedb_add_keeps_fastest_per_config():
    from repro.tune.db import TuneDB
    db = TuneDB()
    db.add(_entry(1024, 50.0))
    db.add(_entry(1024, 80.0))     # same config, slower rerun -> ignored
    db.add(_entry(1024, 30.0))     # same config, faster rerun -> replaces
    assert len(db) == 1
    assert db.best("all_reduce", 1024).us_per_call == 30.0


def test_select_config_cold_cache_falls_back_to_optimized(tmp_path):
    from repro.core.config import OPTIMIZED_CONFIG
    from repro.tune.db import TuneDB, select_config
    assert select_config("all_reduce", 1 << 16,
                         db=TuneDB()) == OPTIMIZED_CONFIG
    # missing file behaves the same
    assert select_config("all_reduce", 1 << 16,
                         path=tmp_path / "nope.json") == OPTIMIZED_CONFIG


def test_select_config_never_crosses_platforms():
    """A config tuned on another platform's cost structure must not beat the
    OPTIMIZED_CONFIG fallback."""
    from repro.core.config import OPTIMIZED_CONFIG
    from repro.tune.db import TuneDB, select_config
    db = TuneDB()
    db.add(_entry(1024, 10.0, topo="cpu:8", window=8))
    # same platform, different device count -> relaxes to it
    assert select_config("all_reduce", 1024, db=db, topo="cpu:4").window == 8
    # different platform -> fallback, never the cpu-tuned entry
    assert select_config("all_reduce", 1024, db=db,
                         topo="tpu:8") == OPTIMIZED_CONFIG


def test_communicator_auto_config_keys_on_comm_size():
    """Communicator.auto_config looks up THIS communicator's size, not the
    whole process's device count."""
    from repro.core.communicator import Communicator
    from repro.tune.db import TuneDB, topology_key
    import repro.tune.db as dbmod

    comm = Communicator(("data",), (4,))
    topo4 = topology_key(n_devices=4)          # e.g. cpu:4 under pytest
    db = TuneDB()
    db.add(_entry(1024, 10.0, topo=topo4, window=8))
    path = dbmod.default_db_path()
    seen = {}
    orig = dbmod.select_config

    def spy(collective, msg_bytes, **kw):
        seen.update(kw)
        return orig(collective, msg_bytes, db=db, topo=kw.get("topo"))

    dbmod.select_config = spy
    try:
        import repro.tune
        repro.tune.select_config, orig_pkg = spy, repro.tune.select_config
        try:
            cfg = comm.auto_config("all_reduce", 1024)
        finally:
            repro.tune.select_config = orig_pkg
    finally:
        dbmod.select_config = orig
    assert seen.get("topo") == topo4
    assert cfg.window == 8


def test_hop_aware_selection_prefers_matched_hops(tmp_path):
    """Per-edge hop-aware selection (the paper's direct-link vs
    Ethernet-switch distinction): a DB with conflicting 1-hop/3-hop winners
    must answer per hop distance, not with the global minimum."""
    from repro.tune.db import TuneDB, select_config

    db = TuneDB()
    # direct links: tiny window wins; routed 3-hop edges: window scaling wins
    db.add(_entry(1024, 10.0, window=1, hops=1))
    db.add(_entry(1024, 12.0, window=8, hops=3))

    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         hops=1).window == 1
    # hop-matched beats globally fastest
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         hops=3).window == 8
    # no hop hint: fastest measurement overall
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8").window == 1
    # unmeasured distance relaxes to the nearest measured one
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         hops=4).window == 8

    # hops survive the JSON round-trip and distinguish add() data points
    path = tmp_path / "tunedb.json"
    db.save(path)
    back = TuneDB.load(path)
    assert len(back) == 2
    assert sorted(e.hops for e in back.entries) == [1, 3]
    assert select_config("all_reduce", 1024, db=back, topo="cpu:8",
                         hops=3).window == 8


def test_tunedb_add_same_config_different_hops_kept():
    from repro.tune.db import TuneDB
    db = TuneDB()
    db.add(_entry(1024, 10.0, hops=1))
    db.add(_entry(1024, 30.0, hops=3))   # same config, other distance: kept
    db.add(_entry(1024, 25.0, hops=3))   # faster rerun at 3 hops: replaces
    assert len(db) == 2
    assert db.best("all_reduce", 1024, "cpu:8", hops=3).us_per_call == 25.0


def test_select_config_returns_measured_best():
    from repro.tune.db import TuneDB, select_config, topology_key
    topo = topology_key()   # this process's topology (cpu:1 under pytest)
    db = TuneDB()
    db.add(_entry(1024, 50.0, topo=topo))
    db.add(_entry(1024, 10.0, topo=topo, window=8))
    cfg = select_config("all_reduce", 1024, db=db)
    assert cfg.window == 8


# ----------------------------------------------------------------------
# Variance-aware selection (p95 near-tie break) + lossy-wire selection
# ----------------------------------------------------------------------

def test_p95_breaks_near_ties():
    """Two configs within NEAR_TIE on the mean: the lower measured tail
    wins; an entry with no recorded p95 cannot win the tie-break."""
    import dataclasses as dc
    from repro.tune.db import TuneDB, select_config, topology_key
    topo = topology_key()
    db = TuneDB()
    # 2% apart on the mean (inside the 5% near-tie band), tails disagree
    db.add(dc.replace(_entry(1024, 100.0, topo=topo), p95_us=180.0))
    db.add(dc.replace(_entry(1024, 102.0, topo=topo, window=8),
                      p95_us=110.0))
    cfg = select_config("all_reduce", 1024, db=db)
    assert cfg.window == 8                   # steadier tail wins the tie
    # an unknown tail never beats a measured one on missing data
    db2 = TuneDB()
    db2.add(_entry(1024, 100.0, topo=topo))              # p95 unrecorded
    db2.add(dc.replace(_entry(1024, 102.0, topo=topo, window=8),
                       p95_us=110.0))
    assert select_config("all_reduce", 1024, db=db2).window == 8
    # outside the near-tie band the mean decides, tails notwithstanding
    db3 = TuneDB()
    db3.add(dc.replace(_entry(1024, 100.0, topo=topo), p95_us=500.0))
    db3.add(dc.replace(_entry(1024, 150.0, topo=topo, window=8),
                       p95_us=101.0))
    assert select_config("all_reduce", 1024, db=db3).window == 4


def test_select_config_prefers_matching_loss():
    """Jumbo frames win the clean sweep, small GUARANTEED segments win the
    lossy one — the answer must come from the matching-loss measurement
    (nearest measured rate when there is no exact match)."""
    import dataclasses as dc
    from repro.core.config import Reliability
    from repro.tune.db import TuneDB, select_config, topology_key
    topo = topology_key()
    db = TuneDB()
    db.add(_entry(1 << 20, 50.0, topo=topo, chunk_bytes=1 << 20))
    db.add(dc.replace(
        _entry(1 << 20, 80.0, topo=topo, chunk_bytes=4096,
               reliability=Reliability.GUARANTEED), loss=0.05))
    clean = select_config("all_reduce", 1 << 20, db=db)
    assert clean.chunk_bytes == 1 << 20
    lossy = select_config("all_reduce", 1 << 20, db=db, loss=0.05)
    assert lossy.chunk_bytes == 4096
    assert lossy.reliability == Reliability.GUARANTEED
    # nearest measured rate answers an unswept loss
    near = select_config("all_reduce", 1 << 20, db=db, loss=0.08)
    assert near.chunk_bytes == 4096


def test_reliability_config_json_roundtrip():
    from repro.core.config import CommConfig, Reliability
    from repro.tune.space import config_from_dict, config_to_dict
    cfg = CommConfig(reliability=Reliability.GUARANTEED, ack_timeout=3,
                     max_retransmits=5, backoff_base=2, backoff_cap=8)
    wire = json.loads(json.dumps(config_to_dict(cfg)))
    assert wire["reliability"] == "guaranteed"
    back = config_from_dict(wire)
    assert back == cfg
    assert back.reliability is Reliability.GUARANTEED
    # best-effort default survives too
    assert config_from_dict(json.loads(json.dumps(
        config_to_dict(CommConfig())))).reliability is \
        Reliability.BEST_EFFORT


# ----------------------------------------------------------------------
# End-to-end objective (overlap-aware selection)
# ----------------------------------------------------------------------

def test_e2e_objective_disagrees_with_latency():
    """The §5 scenario: the bare-latency winner loses the consumer loop.
    select_config must answer per objective."""
    from repro.tune.db import TuneDB, select_config

    db = TuneDB()
    # microbench winner: buffered, but its consumer loop is slow
    db.add(_entry(1024, 10.0, e2e_us=90.0, mode="buffered", window=1))
    # microbench loser: overlapped/chunked, but the consumer hides the comm
    db.add(_entry(1024, 14.0, e2e_us=40.0, window=8))

    assert select_config("all_reduce", 1024, db=db, topo="cpu:8").window == 1
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         objective="latency").window == 1
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         objective="e2e").window == 8
    with pytest.raises(ValueError):
        select_config("all_reduce", 1024, db=db, objective="nope")


def test_e2e_objective_falls_back_to_latency():
    """Entries without a consumer-loop measurement rank by bare latency
    under either objective; measured e2e outranks latency-only entries."""
    from repro.tune.db import TuneDB, select_config
    db = TuneDB()
    db.add(_entry(1024, 10.0, window=1))             # no e2e measured
    db.add(_entry(1024, 20.0, window=8))
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         objective="e2e").window == 1
    # one measured e2e entry beats any latency-only proxy
    db.add(_entry(1024, 30.0, e2e_us=50.0, window=4))
    assert select_config("all_reduce", 1024, db=db, topo="cpu:8",
                         objective="e2e").window == 4


def test_tunedb_e2e_roundtrip_and_merge(tmp_path):
    from repro.tune.db import TuneDB
    db = TuneDB()
    db.add(_entry(1024, 50.0, e2e_us=120.0))
    # slower latency rerun carrying a better e2e: latency keeps 50, e2e 100
    db.add(_entry(1024, 60.0, e2e_us=100.0))
    # faster latency rerun without e2e: latency 40, e2e preserved
    db.add(_entry(1024, 40.0))
    assert len(db) == 1
    e = db.entries[0]
    assert e.us_per_call == 40.0 and e.e2e_us == 100.0
    assert e.latency_us == e.us_per_call     # the alias
    assert e.metric() == 40.0 and e.metric("e2e") == 100.0

    path = tmp_path / "tunedb.json"
    db.save(path)
    back = TuneDB.load(path)
    assert back.entries[0].e2e_us == 100.0
    # pre-e2e DBs (no e2e_us key) still load
    import json
    payload = json.loads(path.read_text())
    for ent in payload["entries"]:
        del ent["e2e_us"]
    path.write_text(json.dumps(payload))
    old = TuneDB.load(path)
    assert old.entries[0].e2e_us == 0.0


def test_e2e_consumer_latency_model():
    """The overlap-aware Eq. 2 consumer term: overlapped hides comm under
    compute (max), fused exposes part of it, host serializes."""
    from repro.core import latmodel
    from repro.core.config import (CommConfig, CommMode, Scheduling, V5E)

    msg, compute = 1 << 20, 50e-6
    over = CommConfig(scheduling=Scheduling.OVERLAPPED)
    fused = CommConfig(scheduling=Scheduling.FUSED)
    host = CommConfig(scheduling=Scheduling.HOST, mode=CommMode.BUFFERED)
    comm_s = latmodel.pingping_latency(msg, over, V5E)
    t_over = latmodel.e2e_consumer_latency(msg, over, compute, V5E)
    t_fused = latmodel.e2e_consumer_latency(msg, fused, compute, V5E)
    t_host = latmodel.e2e_consumer_latency(msg, host, compute, V5E)
    assert t_over == pytest.approx(max(compute, comm_s))   # full hiding
    assert t_over < t_fused < t_host
    # serialized lower/upper bounds hold for any config
    for cfg, t in ((over, t_over), (fused, t_fused), (host, t_host)):
        c = latmodel.pingping_latency(msg, cfg, V5E)
        assert max(compute, c) - 1e-12 <= t <= compute + c + 1e-12


def test_prune_on_e2e_objective_reorders_candidates():
    """Pruning on the e2e objective must keep the overlapped candidate that
    latency-objective pruning ranks as strictly worse."""
    from repro.core.config import CommConfig, Scheduling
    from repro.tune.prune import (calibration_from_db, predicted_e2e,
                                  predicted_latency, prune_candidates)

    cal = calibration_from_db(_synthetic_db(_synthetic_truth_hw()),
                              topo="cpu:8")
    over = CommConfig(scheduling=Scheduling.OVERLAPPED, chunk_bytes=1 << 16)
    fused = CommConfig(scheduling=Scheduling.FUSED)
    msg = 1 << 20
    # bare latency: the chunked overlapped config pays per-chunk commands
    assert predicted_latency(over, msg, cal, "all_reduce") >= \
        predicted_latency(fused, msg, cal, "all_reduce")
    # with hideable compute dominating, e2e prediction flips the order
    compute_s = 10.0 * predicted_latency(fused, msg, cal, "all_reduce")
    assert predicted_e2e(over, msg, cal, compute_s, "all_reduce") < \
        predicted_e2e(fused, msg, cal, compute_s, "all_reduce")
    kept, skipped = prune_candidates([over, fused], msg, cal, ratio=1.05,
                                     collective="all_reduce",
                                     objective="e2e", compute_s=compute_s)
    assert over in kept
    kept_lat, _ = prune_candidates([over, fused], msg, cal, ratio=1.05,
                                   collective="all_reduce")
    assert fused in kept_lat


def test_enumerate_configs_e2e_keeps_overlapped_consumers():
    """Under the e2e objective the overlapped all_reduce variants stay
    distinct (the consumer loop distinguishes them); the latency objective
    still collapses them (the bare collective cannot)."""
    from repro.core.config import Scheduling
    from repro.tune.space import enumerate_configs

    lat = enumerate_configs("all_reduce")
    e2e = enumerate_configs("all_reduce", objective="e2e")
    assert not any(c.scheduling == Scheduling.OVERLAPPED for c in lat)
    assert any(c.scheduling == Scheduling.OVERLAPPED for c in e2e)
    assert len(e2e) > len(lat)
    # non-consumer collectives are unchanged
    assert enumerate_configs("all_gather", objective="e2e") == \
        enumerate_configs("all_gather")


def test_communicator_auto_config_passes_ring_hops():
    """The hop-aware preference must be live from auto_config: the ring
    pattern's worst-case hop distance reaches select_config."""
    from repro.core.communicator import Communicator
    import repro.tune

    comm = Communicator(("data",), (8,))     # 2x4 torus -> max ring hop 2
    seen = {}
    orig = repro.tune.select_config

    def spy(collective, msg_bytes, **kw):
        seen.update(kw)
        return orig(collective, msg_bytes, **kw)

    repro.tune.select_config = spy
    try:
        comm.auto_config("all_reduce", 1024)
        assert seen.get("hops") == comm.max_hops(comm.ring_perm())
        assert seen.get("hops", 0) >= 1
        assert seen.get("objective") == "latency"
        comm.auto_config("all_reduce", 1024, hops=3, objective="e2e")
        assert seen.get("hops") == 3 and seen.get("objective") == "e2e"
    finally:
        repro.tune.select_config = orig


def test_program_cache_key_separates_mesh_factorizations():
    """topology_key is platform:n_devices only — the program-cache key must
    additionally carry the mesh structure, or an 8-rank-axis sweep and a
    4x2 inner/outer sweep (same device count) would replay each other's
    compiled programs and record silently wrong measurements."""
    from repro.tune.sweep import _mesh_key

    class FakeDevs:
        def __init__(self, shape):
            self.shape = shape

    class FakeMesh:
        def __init__(self, axis_names, shape):
            self.axis_names = axis_names
            self.devices = FakeDevs(shape)

    flat = _mesh_key(FakeMesh(("x",), (8,)))
    two_axis = _mesh_key(FakeMesh(("inner", "outer"), (4, 2)))
    assert flat != two_axis
    assert _mesh_key(FakeMesh(("x",), (8,))) == flat


def test_e2e_sweep_records_consumer_loop(tmp_path):
    out = run_multidevice("""
from repro import compat
from repro.tune import TuneDB, run_sweep, select_config
from repro.tune.sweep import sweep_summary

mesh = compat.make_mesh((8,), ("x",))
stats = {}
db = run_sweep(mesh=mesh, collectives=("all_reduce",), sizes=(16384,),
               fast=True, max_configs=6, reps=1, inner=2,
               objective="e2e", stats=stats)
ents = [e for e in db.entries if e.collective == "all_reduce"]
assert ents and all(e.e2e_us > 0.0 for e in ents), stats
assert stats["e2e_measured"] == len(ents), stats
cfg = select_config("all_reduce", 16384, db=db, topo=ents[0].topo,
                    objective="e2e")
best_e2e = min(e.e2e_us for e in ents)
picked = [e for e in ents if e.e2e_us == best_e2e]
assert cfg == picked[0].comm_config
assert "consumer-loop e2e" in sweep_summary(stats)
print("E2E SWEEP OK")
""")
    assert "E2E SWEEP OK" in out


def test_moe_all_to_all_e2e_sweep_selects_measured_best(tmp_path):
    """The MoE dispatch -> expert-FFN -> combine loop is the third CONSUMERS
    entry: an e2e-objective all_to_all sweep must record consumer-loop times
    and select_config(objective='e2e') must return the measured winner."""
    out = run_multidevice("""
from repro import compat
from repro.tune import TuneDB, run_sweep, select_config
from repro.tune.sweep import CONSUMERS, consumer_flops

assert CONSUMERS["all_to_all"] == ("moe_loop",)
assert consumer_flops("all_to_all", 1 << 14) > 0

mesh = compat.make_mesh((8,), ("x",))
stats = {}
db = run_sweep(mesh=mesh, collectives=("all_to_all",), sizes=(16384,),
               fast=True, max_configs=5, reps=1, inner=2,
               objective="e2e", stats=stats)
ents = [e for e in db.entries if e.collective == "all_to_all"]
assert ents and all(e.e2e_us > 0.0 for e in ents), stats
assert stats["e2e_measured"] == len(ents), stats
cfg = select_config("all_to_all", 16384, db=db, topo=ents[0].topo,
                    objective="e2e")
best = min(ents, key=lambda e: e.e2e_us)
assert cfg == best.comm_config
print("MOE E2E SWEEP OK")
""")
    assert "MOE E2E SWEEP OK" in out


def test_consumer_axis_prefers_matching_entries():
    """The TuneDB's consumer axis: a decode_step caller is answered by the
    decode_step-loop measurement when one exists, a prefill caller by the
    prefill-loop one — distinct winners from the same DB — and an unswept
    consumer relaxes to every entry instead of failing."""
    from repro.core.config import CommConfig, CommMode, Scheduling
    from repro.tune.db import TuneDB, TuneEntry, select_config
    from repro.tune.space import config_to_dict

    fast_small = CommConfig(scheduling=Scheduling.OVERLAPPED,
                            chunk_bytes=4096)
    fast_big = CommConfig(mode=CommMode.BUFFERED)
    db = TuneDB()
    for consumer, winner, loser in (("decode_step", fast_small, fast_big),
                                    ("prefill", fast_big, fast_small)):
        db.add(TuneEntry(topo="cpu:8", collective="all_reduce",
                         msg_bytes=16384, config=config_to_dict(winner),
                         us_per_call=10.0, e2e_us=20.0, consumer=consumer))
        db.add(TuneEntry(topo="cpu:8", collective="all_reduce",
                         msg_bytes=16384, config=config_to_dict(loser),
                         us_per_call=9.0, e2e_us=55.0, consumer=consumer))
    # 4 distinct (config, consumer) entries survive add()'s merge.
    assert len(db.entries) == 4
    pick = lambda c: select_config(  # noqa: E731
        "all_reduce", 16384, db=db, topo="cpu:8", objective="e2e",
        consumer=c)
    assert pick("decode_step") == fast_small
    assert pick("prefill") == fast_big
    # Unswept consumer: relax to all entries (global e2e winner), and the
    # bare-latency objective ignores the consumer-loop measurements.
    assert pick("halo_fold") == fast_small
    assert select_config("all_reduce", 16384, db=db, topo="cpu:8",
                         objective="latency") == fast_big
    # Round-trips through JSON (old DBs load with consumer="" defaults).
    entries = TuneDB([TuneEntry(**d) for d in
                      [dataclasses.asdict(e) for e in db.entries]])
    assert {e.consumer for e in entries.entries} == {"decode_step", "prefill"}


# ----------------------------------------------------------------------
# Calibration
# ----------------------------------------------------------------------

def test_calibration_recovers_known_constants():
    """Fitting on synthetic Eq. 1 timings must recover the generating
    HardwareSpec constants."""
    from repro.core import latmodel
    from repro.core.config import (CommConfig, CommMode, HardwareSpec,
                                   Scheduling)
    from repro.tune.calibrate import fit_latency_model

    hw = HardwareSpec(host_dispatch=25e-6, fused_dispatch=0.8e-6,
                      ici_latency=1.5e-6, ici_bw=40e9, hbm_bw=600e9)
    meas = []
    for mode in CommMode:
        for sched in Scheduling:
            for size in (1 << 10, 1 << 14, 1 << 17, 1 << 20):
                cfg = CommConfig(mode=mode, scheduling=sched)
                meas.append((cfg, size,
                             latmodel.pingping_latency(size, cfg, hw)))
    r = fit_latency_model(meas)
    assert r.l_k_host == pytest.approx(hw.host_dispatch, rel=0.15)
    assert r.l_k_fused == pytest.approx(hw.fused_dispatch, rel=0.25)
    assert r.link_bw == pytest.approx(hw.ici_bw, rel=0.15)
    assert r.staging_bw == pytest.approx(hw.hbm_bw, rel=0.15)
    assert r.rms_rel_err < 0.05

    # and the calibrated spec reproduces the measurements through latmodel
    cal = r.to_hardware_spec(hw)
    for cfg, size, sec in meas:
        assert latmodel.pingping_latency(size, cfg, cal) == pytest.approx(
            sec, rel=0.1)


def test_calibration_report_and_db_path():
    from repro.core.config import CommConfig, CommMode, Scheduling, V5E
    from repro.core import latmodel
    from repro.tune.calibrate import calibrate_from_db, model_vs_measured
    from repro.tune.db import TuneDB, TuneEntry
    from repro.tune.space import config_to_dict

    db = TuneDB()
    for mode in CommMode:
        for sched in Scheduling:
            for size in (1 << 12, 1 << 16, 1 << 20):
                cfg = CommConfig(mode=mode, scheduling=sched)
                sec = latmodel.pingping_latency(size, cfg, V5E)
                db.add(TuneEntry(topo="cpu:8", collective="sendrecv",
                                 msg_bytes=size,
                                 config=config_to_dict(cfg),
                                 us_per_call=sec * 1e6))
    r = calibrate_from_db(db)
    assert "l_k(host)" in r.summary()
    rows = model_vs_measured(r, db)
    assert len(rows) == len(db)
    assert all("ratio=" in row for row in rows)


def test_fit_latency_model_empty_raises():
    from repro.tune.calibrate import fit_latency_model
    with pytest.raises(ValueError):
        fit_latency_model([])


# ----------------------------------------------------------------------
# Calibration-driven pruning (model-guided search)
# ----------------------------------------------------------------------

def _synthetic_truth_hw():
    """Ground-truth substrate for the synthetic-TuneDB pruning regression:
    realistic dispatch-cost separation (30 us host vs 0.5 us fused)."""
    from repro.core.config import HardwareSpec
    return HardwareSpec(host_dispatch=30e-6, fused_dispatch=0.5e-6,
                        ici_latency=1e-6, ici_bw=50e9, hbm_bw=819e9)


def _synthetic_db(hw, noise=0.03):
    """sendrecv measurements = ground-truth Eq.1 latency x (1 +- noise)."""
    import numpy as np
    from repro.core import latmodel
    from repro.tune.db import TuneDB, TuneEntry
    from repro.tune.space import config_to_dict, enumerate_configs
    rng = np.random.RandomState(7)
    db = TuneDB()
    for size in (1 << 10, 1 << 14, 1 << 17, 1 << 20):
        for cfg in enumerate_configs("sendrecv"):
            sec = latmodel.pingping_latency(size, cfg, hw)
            sec *= 1.0 + noise * rng.randn()
            db.add(TuneEntry(topo="cpu:8", collective="sendrecv",
                             msg_bytes=size, config=config_to_dict(cfg),
                             us_per_call=sec * 1e6))
    return db


def test_pruning_skips_30pct_and_keeps_winner_within_noise():
    """The acceptance regression: on the standard sweep space the calibrated
    model must skip >= 30% of candidates while the pruned sweep's winner
    stays within measurement noise of the exhaustive winner."""
    import numpy as np
    from repro.core import latmodel
    from repro.tune.prune import calibration_from_db, prune_candidates
    from repro.tune.space import enumerate_configs

    hw = _synthetic_truth_hw()
    noise = 0.03
    cal = calibration_from_db(_synthetic_db(hw, noise), topo="cpu:8")
    assert cal is not None and cal.rms_rel_err < 0.15

    rng = np.random.RandomState(11)

    def measure(cfg, size):  # synthetic measurement = truth x noise
        return (latmodel.pingping_latency(size, cfg, hw)
                * (1.0 + noise * rng.randn()))

    total = kept_total = 0
    for coll in ("all_reduce", "sendrecv", "all_to_all"):
        cands = enumerate_configs(coll)
        for size in (1 << 10, 1 << 14, 1 << 17, 1 << 20):
            kept, skipped = prune_candidates(cands, size, cal,
                                             collective=coll)
            assert kept, (coll, size)
            total += len(cands)
            kept_total += len(kept)
            # winner parity: best measured config among the kept set is
            # within noise of the best over the exhaustive set
            measured = {id(c): measure(c, size) for c in cands}
            best_all = min(cands, key=lambda c: measured[id(c)])
            best_kept = min(kept, key=lambda c: measured[id(c)])
            t_all = latmodel.pingping_latency(size, best_all, hw)
            t_kept = latmodel.pingping_latency(size, best_kept, hw)
            assert t_kept <= t_all * (1.0 + 5 * noise), (coll, size)
    skipped_frac = 1.0 - kept_total / total
    assert skipped_frac >= 0.30, f"pruned only {skipped_frac:.0%}"


def test_prune_candidates_always_keeps_incumbent():
    from repro.tune.prune import calibration_from_db, predicted_latency, \
        prune_candidates
    from repro.tune.space import enumerate_configs

    hw = _synthetic_truth_hw()
    cal = calibration_from_db(_synthetic_db(hw), topo="cpu:8")
    cands = enumerate_configs("all_reduce")
    kept, skipped = prune_candidates(cands, 1 << 14, cal,
                                     collective="all_reduce")
    assert len(kept) + len(skipped) == len(cands)
    preds = {id(c): predicted_latency(c, 1 << 14, cal, "all_reduce")
             for c in cands}
    best = min(preds.values())
    assert all(preds[id(c)] <= 2.0 * best for c in kept)
    assert all(preds[id(c)] > 2.0 * best for c in skipped)


def test_calibration_from_db_cold_cache_returns_none():
    from repro.tune.db import TuneDB
    from repro.tune.prune import calibration_from_db
    assert calibration_from_db(TuneDB(), topo="cpu:8") is None


def test_chunk_aware_prediction_prices_small_segments():
    """The Eq.3-style per-chunk command term: a 64 KiB-segment streaming
    sendrecv at 1 MiB must be predicted ~16 commands' worth slower than the
    jumbo config; non-chunking collectives see a single command."""
    import dataclasses
    from repro.core.config import CommConfig
    from repro.tune.prune import calibration_from_db, predicted_latency

    cal = calibration_from_db(_synthetic_db(_synthetic_truth_hw()),
                              topo="cpu:8")
    jumbo = CommConfig(chunk_bytes=1 << 20)
    small = dataclasses.replace(jumbo, chunk_bytes=1 << 16)
    msg = 1 << 20
    t_jumbo = predicted_latency(jumbo, msg, cal, "sendrecv")
    t_small = predicted_latency(small, msg, cal, "sendrecv")
    assert t_small > t_jumbo
    # all_reduce never splits the wire: segment size is prediction-neutral
    assert predicted_latency(small, msg, cal, "all_reduce") == \
        predicted_latency(jumbo, msg, cal, "all_reduce")


def test_sweep_new_collectives_and_pruning_e2e(tmp_path):
    out = run_multidevice("""
from repro import compat
from repro.tune import CalibrationResult, TuneDB, run_sweep

mesh = compat.make_mesh((8,), ("x",))
cal = CalibrationResult(l_k_host=30e-6, l_k_fused=0.5e-6,
                        link_latency=1e-6, link_bw=50e9, staging_bw=819e9,
                        n_points=16, rms_rel_err=0.05)
stats = {}
db = run_sweep(mesh=mesh,
               collectives=("all_to_all", "hierarchical_all_reduce"),
               sizes=(1024,), fast=True, reps=1, inner=2,
               prune=True, calibration=cal, stats=stats)
colls = {e.collective for e in db.entries}
assert "all_to_all" in colls and "hierarchical_all_reduce" in colls, colls
assert stats["pruned"] > 0, stats
assert stats["measured"] < stats["total"], stats
assert stats["wall_s"] > 0 and stats["est_exhaustive_s"] > stats["wall_s"]
print("NEW COLLECTIVE SWEEP OK", stats["measured"], stats["total"])
""")
    assert "NEW COLLECTIVE SWEEP OK" in out


# ----------------------------------------------------------------------
# Latmodel regressions (the tuner's cost model)
# ----------------------------------------------------------------------

def test_buffered_peak_bw_formula():
    """Series-bandwidth law: (1/bw_link + 1/(bw_mem/2))^-1, and the paper's
    own numbers: 12.5 GB/s link + 14 GB/s mem -> 6.6 GB/s."""
    import dataclasses as dc
    from repro.core import latmodel
    from repro.core.config import V5E
    expect = 1.0 / (1.0 / V5E.ici_bw + 2.0 / V5E.hbm_bw)
    assert latmodel.buffered_peak_bw(V5E) == pytest.approx(expect)
    fpga = dc.replace(V5E, ici_bw=12.5e9, hbm_bw=2 * 14e9)
    assert latmodel.buffered_peak_bw(fpga) == pytest.approx(6.6e9, rel=0.01)


def test_stall_fraction_monotone_in_l_k():
    """More dispatch latency can only stall the pipeline more (paper Fig. 9:
    the MPI baseline's 30 us l_k is what produces the 75-80% stall)."""
    import dataclasses as dc
    from repro.core import latmodel
    from repro.core.config import BASELINE_CONFIG, V5E
    w = latmodel.SWEWorkload(
        e_total=48000, e_core=5600, e_send=270, e_recv=270, d_ext=0,
        l_pipe=100, n_max=4, flop_per_element=260.0, freq=256e6,
        msg_bytes=810)
    stalls = [latmodel.stall_fraction(
        w, BASELINE_CONFIG, dc.replace(V5E, host_dispatch=lk))
        for lk in (1e-6, 5e-6, 15e-6, 30e-6, 60e-6)]
    assert all(a <= b for a, b in zip(stalls, stalls[1:]))
    assert stalls[-1] > stalls[0]
    # throughput moves the other way
    thr = [latmodel.eq2_throughput(
        w, BASELINE_CONFIG, dc.replace(V5E, host_dispatch=lk))
        for lk in (1e-6, 30e-6, 60e-6)]
    assert thr[0] >= thr[1] >= thr[2]


# ----------------------------------------------------------------------
# Measured sweep -> selection -> SWE driver, end to end (8 devices)
# ----------------------------------------------------------------------

def test_sweep_select_and_auto_driver_e2e(tmp_path):
    out = run_multidevice(f"""
import jax
from repro import compat
from repro.tune import TuneDB, run_sweep, select_config
from repro.core.config import CommConfig

mesh = compat.make_mesh((8,), ("x",))
db = run_sweep(mesh=mesh, collectives=("sendrecv",), sizes=(1024,),
               fast=True, max_configs=2, reps=1, inner=2)
assert len(db) >= 1, "sweep produced no entries"
path = db.save(r"{tmp_path / 'tunedb.json'}")
cfg = select_config("sendrecv", 1024, mesh=mesh, path=path)
assert isinstance(cfg, CommConfig)

# the SWE driver consumes the same TuneDB via comm_cfg="auto"
from repro.swe import driver
dmesh = compat.make_mesh((8,), ("data",))
sim = driver.build_simulation(400, dmesh, "auto", tune_db_path=path)
assert isinstance(sim.comm_cfg, CommConfig)
s = driver.make_sim_runner(sim, 3)(sim.state, 0.0)
jax.block_until_ready(s)
print("TUNE E2E OK")
""")
    assert "TUNE E2E OK" in out
