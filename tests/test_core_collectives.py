"""ACCL-X collective correctness: every algorithm/mode/transport/compression
combination must agree with the plain-numpy reference on an 8-device mesh."""
import numpy as np
import pytest

from helpers import run_multidevice


def test_all_reduce_all_algorithms():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import (CommConfig, Compression, Communicator, collectives)

mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(0).randn(8, 40).astype(np.float32)
ref = x.sum(0)
for name, cfg, tol in [
    ("native", CommConfig(), 1e-5),
    ("ring", CommConfig(algorithm="ring"), 1e-5),
    ("ring_int8", CommConfig(algorithm="ring", compression=Compression.INT8), 2e-1),
    ("ring_bf16", CommConfig(algorithm="ring", compression=Compression.BF16), 1e-1),
]:
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def f(xs):
        return collectives.all_reduce(xs[0], comm, cfg)[None]
    out = np.asarray(f(x))
    assert np.allclose(out, np.broadcast_to(ref, out.shape),
                       atol=tol * (np.abs(ref).max() + 1)), name
print("OK")
""")
    assert "OK" in out


def test_sendrecv_modes_and_transports():
    out = run_multidevice("""
import jax, numpy as np
from repro import compat
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import CommConfig, CommMode, Transport, Communicator, collectives

mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(1).randn(8, 130).astype(np.float32)
for mode in (CommMode.STREAMING, CommMode.BUFFERED):
    for tr in (Transport.ORDERED, Transport.UNORDERED):
        for chunk in (512, 2048):
            cfg = CommConfig(mode=mode, transport=tr, chunk_bytes=chunk, window=2)
            @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
            def g(xs):
                return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]
            out = np.asarray(g(x))
            assert np.allclose(out, np.roll(x, 1, axis=0)), (mode, tr, chunk)
print("OK")
""")
    assert "OK" in out


def test_reduce_scatter_and_gather_roundtrip():
    out = run_multidevice("""
import jax, numpy as np
from repro import compat
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import CommConfig, Communicator, collectives

mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(2).randn(8, 16, 5).astype(np.float32)
for algo in ("native", "ring"):
    cfg = CommConfig(algorithm=algo)
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def rs(xs):
        seg = collectives.reduce_scatter(xs[0], comm, cfg)
        return collectives.all_gather(seg, comm, cfg, axis=0)[None]
    out = np.asarray(rs(x))
    ref = x.sum(0)
    assert np.allclose(out[0], ref, atol=1e-4), algo
print("OK")
""")
    assert "OK" in out


def test_hierarchical_all_reduce_multipod():
    out = run_multidevice("""
import jax, numpy as np
from repro import compat
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import CommConfig, Communicator, collectives

mesh = jax.make_mesh((2, 4), ("pod", "data"))
ci = Communicator.from_mesh(mesh, "data")
co = Communicator.from_mesh(mesh, "pod")
x = np.random.RandomState(3).randn(2, 4, 33).astype(np.float32)
@partial(compat.shard_map, mesh=mesh, in_specs=P("pod", "data"),
         out_specs=P("pod", "data"))
def f(xs):
    return collectives.hierarchical_all_reduce(
        xs[0, 0], ci, co, CommConfig())[None, None]
out = np.asarray(f(x))
assert np.allclose(out, np.broadcast_to(x.sum((0, 1)), out.shape), atol=1e-4)
print("OK")
""")
    assert "OK" in out


def test_edge_color_rounds_properties():
    from repro.core.collectives import edge_color_rounds
    import itertools
    rng = np.random.RandomState(0)
    for trial in range(20):
        n = rng.randint(3, 10)
        edges = set()
        for _ in range(rng.randint(1, 3 * n)):
            s, d = rng.randint(0, n, 2)
            if s != d:
                edges.add((int(s), int(d)))
        rounds = edge_color_rounds(sorted(edges))
        # every edge appears exactly once
        flat = [e for r in rounds for e in r]
        assert sorted(flat) == sorted(edges)
        # each round is ppermute-valid
        for r in rounds:
            srcs = [s for s, _ in r]
            dsts = [d for _, d in r]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
