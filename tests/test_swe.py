"""Shallow-water reproduction correctness (the paper's application)."""
import numpy as np
import pytest

from helpers import run_multidevice


def test_mesh_generation_properties():
    from repro.swe.mesh_gen import generate_bight_mesh
    mesh = generate_bight_mesh(800, seed=1)
    assert mesh.n_elements > 300
    assert (mesh.neighbors == -2).sum() > 0          # has open-sea edges
    assert (mesh.neighbors == -1).sum() > 0          # has land edges
    assert (mesh.area > 0).all()
    # outward normals: each element's normals sum to ~0 (closed polygon)
    assert np.abs(mesh.normals.sum(axis=1)).max() < 1e-9
    # adjacency is symmetric
    for e in range(0, mesh.n_elements, 7):
        for j in range(3):
            n = mesh.neighbors[e, j]
            if n >= 0:
                assert e in mesh.neighbors[n], (e, n)


def test_partition_schedule_valid():
    from repro.swe.mesh_gen import generate_bight_mesh
    from repro.swe.partition import partition_mesh
    from repro.swe.dg_solver import initial_state
    mesh = generate_bight_mesh(800, seed=1)
    pm = partition_mesh(mesh, 8, initial_state(mesh))
    # every round is a valid ppermute (each rank sends/receives <= once)
    for perm in pm.rounds:
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
    assert pm.n_max >= 1
    assert pm.n_rounds >= pm.n_max   # rounds cover the neighbor count
    # element conservation
    assert int(pm.valid.sum()) == mesh.n_elements


def test_hypothesis_partition_balance():
    from helpers import require_hypothesis
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from repro.swe.partition import _rcb

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 16), st.integers(50, 400))
    def check(parts, n):
        rng = np.random.RandomState(n)
        cent = rng.rand(n, 2)
        pid = _rcb(cent, parts)
        counts = np.bincount(pid, minlength=parts)
        assert counts.max() - counts.min() <= max(2, n // parts // 4 + 1)
        assert counts.sum() == n

    check()


def test_partitioned_equals_single_and_modes():
    out = run_multidevice("""
import jax, numpy as np
from repro.core.config import CommConfig, CommMode, BASELINE_CONFIG
from repro.swe import driver
from repro.swe.partition import _rcb

def flatten(sim, s):
    part = _rcb(sim.mesh.centroids, sim.pm.n_parts)
    counts = np.zeros(sim.pm.n_parts, int)
    vals = np.zeros((sim.mesh.n_elements, 3))
    for e in range(sim.mesh.n_elements):
        p = part[e]
        vals[e] = s[p, counts[p]]
        counts[p] += 1
    return vals

mesh1 = jax.make_mesh((1,), ("data",))
sim1 = driver.build_simulation(500, mesh1, CommConfig())
v1 = flatten(sim1, np.asarray(driver.make_sim_runner(sim1, 20)(sim1.state, 0.0)))

mesh8 = jax.make_mesh((8,), ("data",))
for cfg in (CommConfig(), CommConfig(mode=CommMode.BUFFERED)):
    sim8 = driver.build_simulation(500, mesh8, cfg)
    v8 = flatten(sim8, np.asarray(driver.make_sim_runner(sim8, 20)(sim8.state, 0.0)))
    assert np.abs(v1 - v8).max() < 1e-4, cfg.mode

# host-scheduled baseline
simh = driver.build_simulation(500, mesh8, BASELINE_CONFIG)
runner = driver.make_host_scheduled_runner(simh)
sh, _ = runner.run(simh.state, 0.0, 20)
assert np.abs(v1 - flatten(simh, np.asarray(sh))).max() < 1e-4
assert runner.dispatches == 40
print("SWE PARITY OK")
""")
    assert "SWE PARITY OK" in out


def test_mass_conservation_multidevice():
    out = run_multidevice("""
import jax, numpy as np
from repro.core.config import CommConfig
from repro.swe import driver
mesh = jax.make_mesh((8,), ("data",))
sim = driver.build_simulation(600, mesh, CommConfig())
m0 = float(np.sum(np.asarray(sim.state)[..., 0] * sim.pm.area * sim.pm.valid))
s = driver.make_sim_runner(sim, 50)(sim.state, 0.0)
m1 = float(np.sum(np.asarray(s)[..., 0] * sim.pm.area * sim.pm.valid))
assert abs(m1 - m0) / m0 < 5e-3, (m0, m1)
assert np.isfinite(np.asarray(s)).all()
print("MASS OK", m0, m1)
""")
    assert "MASS OK" in out


def test_eq2_eq3_model_properties():
    """The latency model reproduces the paper's qualitative claims."""
    from repro.core import latmodel
    from repro.core.config import (BASELINE_CONFIG, CommConfig, CommMode,
                                   Scheduling, V5E)
    streaming = CommConfig()
    w = latmodel.SWEWorkload(
        e_total=6000 * 8, e_core=5600, e_send=270, e_recv=270, d_ext=0,
        l_pipe=100, n_max=4, flop_per_element=260.0, freq=256e6,
        msg_bytes=270 * 12 // 4)
    # 1) buffered+host (MPI baseline) latency >> streaming+fused
    l_base = latmodel.eq3_l_comm(w, BASELINE_CONFIG, V5E)
    l_accl = latmodel.eq3_l_comm(w, streaming, V5E)
    assert l_base > 3 * l_accl
    # 2) the baseline stalls the pipeline like the paper (75-80% there)
    assert latmodel.stall_fraction(w, BASELINE_CONFIG, V5E) > 0.4
    assert latmodel.stall_fraction(w, streaming, V5E) < 0.1
    # 3) throughput monotonically degrades with N_max (Fig. 10 steps)
    thr = []
    for nmax in (1, 2, 4, 8, 12):
        import dataclasses
        w2 = dataclasses.replace(w, n_max=nmax)
        thr.append(latmodel.eq2_throughput(w2, BASELINE_CONFIG, V5E))
    assert all(a >= b for a, b in zip(thr, thr[1:]))
    # 4) buffered mode caps below link bandwidth. NOTE the hardware
    # adaptation: on the FPGA the staging copy HALVED peak (6.6 vs 12.5 GB/s,
    # mem ~ link speed); on TPU HBM is 16x faster than ICI so the buffered
    # THROUGHPUT penalty is ~11% — the buffered LATENCY penalty (l_m + the
    # extra l_k) is what dominates instead (asserted in 1-2 above).
    assert latmodel.buffered_peak_bw(V5E) < V5E.ici_bw
    assert latmodel.buffered_peak_bw(V5E) > 0.8 * V5E.ici_bw
