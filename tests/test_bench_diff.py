"""benchmarks/diff.py: the BENCH_comm.json regression gate."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import diff as bench_diff  # noqa: E402


def _write(path, rows):
    path.write_text(json.dumps({"schema": "repro-bench-v1", "rows": rows}))
    return str(path)


@pytest.fixture
def fixture_jsons(tmp_path):
    old = _write(tmp_path / "old.json", {
        "fig9_accl_udp_p8": {"us_per_call": 100.0, "derived": ""},
        "fig9_base_mpi_p8": {"us_per_call": 500.0, "derived": ""},
        "fig9_gone": {"us_per_call": 50.0, "derived": ""},
        "fig3_full_ring_hlo_ops": {"us_per_call": 120.0, "derived": ""},
        "zero_row": {"us_per_call": 0.0, "derived": ""},
    })
    new = _write(tmp_path / "new.json", {
        "fig9_accl_udp_p8": {"us_per_call": 130.0, "derived": ""},   # +30%
        "fig9_base_mpi_p8": {"us_per_call": 300.0, "derived": ""},   # -40%
        "fig3_full_ring_hlo_ops": {"us_per_call": 400.0, "derived": ""},
        "zero_row": {"us_per_call": 9.0, "derived": ""},
    })
    return old, new


def test_compare_classifies_rows(fixture_jsons):
    old, new = fixture_jsons
    regs, imps, missing = bench_diff.compare(
        bench_diff.load_rows(old), bench_diff.load_rows(new), threshold=0.2)
    assert [r[0] for r in regs] == ["fig9_accl_udp_p8"]
    assert regs[0][3] == pytest.approx(1.3)
    assert [i[0] for i in imps] == ["fig9_base_mpi_p8"]
    assert missing == ["fig9_gone"]
    # fig3_* is a count, not a latency — a 3.3x increase is NOT a regression;
    # zero-valued baselines are skipped (no division blowup)
    assert all(not r[0].startswith("fig3_") for r in regs)


def test_main_exit_codes(fixture_jsons, capsys):
    old, new = fixture_jsons
    assert bench_diff.main(["--old", old, "--new", new]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION fig9_accl_udp_p8" in out
    # report-only: same report, exit 0
    assert bench_diff.main(["--old", old, "--new", new, "--report-only"]) == 0
    # tighter threshold flips the improvement row into "not a regression"
    # but a 60% threshold clears the 30% regression
    assert bench_diff.main(["--old", old, "--new", new,
                            "--threshold", "0.6"]) == 0


def test_main_no_regressions_when_identical(tmp_path):
    rows = {"fig9_x_p2": {"us_per_call": 10.0, "derived": ""}}
    old = _write(tmp_path / "a.json", rows)
    new = _write(tmp_path / "b.json", rows)
    assert bench_diff.main(["--old", old, "--new", new]) == 0


def test_main_bad_input(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    ok = _write(tmp_path / "ok.json", {})
    # malformed baseline: hard mode fails, report-only tolerates
    assert bench_diff.main(["--old", str(bad), "--new", ok]) == 2
    assert bench_diff.main(["--old", str(bad), "--new", ok,
                            "--report-only"]) == 0
    assert bench_diff.main(["--old", ok, "--new",
                            str(tmp_path / "nope.json")]) == 2
