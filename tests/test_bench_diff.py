"""benchmarks/diff.py: the BENCH_comm.json regression gate."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import diff as bench_diff  # noqa: E402


def _write(path, rows):
    path.write_text(json.dumps({"schema": "repro-bench-v1", "rows": rows}))
    return str(path)


@pytest.fixture
def fixture_jsons(tmp_path):
    old = _write(tmp_path / "old.json", {
        "fig9_accl_udp_p8": {"us_per_call": 100.0, "derived": ""},
        "fig9_base_mpi_p8": {"us_per_call": 500.0, "derived": ""},
        "fig9_gone": {"us_per_call": 50.0, "derived": ""},
        "fig3_full_ring_hlo_ops": {"us_per_call": 120.0, "derived": ""},
        "topo_hop_ratio_sendrecv": {"us_per_call": 1.5, "derived": ""},
        "zero_row": {"us_per_call": 0.0, "derived": ""},
    })
    new = _write(tmp_path / "new.json", {
        "fig9_accl_udp_p8": {"us_per_call": 130.0, "derived": ""},   # +30%
        "fig9_base_mpi_p8": {"us_per_call": 300.0, "derived": ""},   # -40%
        "fig3_full_ring_hlo_ops": {"us_per_call": 400.0, "derived": ""},
        "topo_hop_ratio_sendrecv": {"us_per_call": 4.5, "derived": ""},
        "zero_row": {"us_per_call": 9.0, "derived": ""},
    })
    return old, new


def test_compare_classifies_rows(fixture_jsons):
    old, new = fixture_jsons
    regs, imps, missing = bench_diff.compare(
        bench_diff.load_rows(old), bench_diff.load_rows(new), threshold=0.2)
    assert [r[0] for r in regs] == ["fig9_accl_udp_p8"]
    assert regs[0][3] == pytest.approx(1.3)
    assert [i[0] for i in imps] == ["fig9_base_mpi_p8"]
    assert missing == ["fig9_gone"]
    # fig3_* is a count and topo_hop_ratio_* a ratio, not latencies — a 3x
    # increase is NOT a regression there; zero-valued baselines are skipped
    # (no division blowup)
    assert all(not r[0].startswith(("fig3_", "topo_hop_ratio")) for r in regs)


def test_main_exit_codes(fixture_jsons, capsys):
    old, new = fixture_jsons
    assert bench_diff.main(["--old", old, "--new", new]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION fig9_accl_udp_p8" in out
    # report-only: same report, exit 0
    assert bench_diff.main(["--old", old, "--new", new, "--report-only"]) == 0
    # tighter threshold flips the improvement row into "not a regression"
    # but a 60% threshold clears the 30% regression
    assert bench_diff.main(["--old", old, "--new", new,
                            "--threshold", "0.6"]) == 0


def test_main_no_regressions_when_identical(tmp_path):
    rows = {"fig9_x_p2": {"us_per_call": 10.0, "derived": ""}}
    old = _write(tmp_path / "a.json", rows)
    new = _write(tmp_path / "b.json", rows)
    assert bench_diff.main(["--old", old, "--new", new]) == 0


def test_multi_baseline_enforcement(tmp_path):
    """Rows need >= 2 committed baselines to hard-fail; the reference is the
    most lenient baseline.  The e2e_ rows graduated with bench_pr4 +
    bench_pr5; the topo_ hop rows with bench_pr5 + bench_pr6 — both are
    enforced now."""
    b1 = _write(tmp_path / "b1.json", {
        "fig9_accl_udp_p8": {"us_per_call": 100.0, "derived": ""},
        "fig9_new_row": {"us_per_call": 10.0, "derived": ""},
        "e2e_rowpar_lat_winner_us": {"us_per_call": 40.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 30.0, "derived": ""},
    })
    b2 = _write(tmp_path / "b2.json", {
        "fig9_accl_udp_p8": {"us_per_call": 120.0, "derived": ""},
        "e2e_rowpar_lat_winner_us": {"us_per_call": 45.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 35.0, "derived": ""},
    })
    # everything regressed 2x vs the lenient baseline
    new = _write(tmp_path / "new.json", {
        "fig9_accl_udp_p8": {"us_per_call": 240.0, "derived": ""},
        "fig9_new_row": {"us_per_call": 20.0, "derived": ""},
        "e2e_rowpar_lat_winner_us": {"us_per_call": 90.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 80.0, "derived": ""},
    })
    # the 2-baseline fig9, e2e AND topo rows are enforced -> exit 1
    assert bench_diff.main(["--old", b1, "--old", b2, "--new", new]) == 1
    # an e2e-only regression now gates too (promotion regression test)
    e2e_only = _write(tmp_path / "e2e_only.json", {
        "fig9_accl_udp_p8": {"us_per_call": 110.0, "derived": ""},
        "fig9_new_row": {"us_per_call": 20.0, "derived": ""},
        "e2e_rowpar_lat_winner_us": {"us_per_call": 90.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 35.0, "derived": ""},
    })
    assert bench_diff.main(["--old", b1, "--old", b2, "--new", e2e_only]) == 1
    # a topo_-only regression gates as well (PR 6 promotion)
    topo_only = _write(tmp_path / "topo_only.json", {
        "fig9_accl_udp_p8": {"us_per_call": 110.0, "derived": ""},
        "fig9_new_row": {"us_per_call": 20.0, "derived": ""},
        "e2e_rowpar_lat_winner_us": {"us_per_call": 45.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 80.0, "derived": ""},
    })
    assert bench_diff.main(["--old", b1, "--old", b2, "--new", topo_only]) == 1
    # remove the enforced regressions: single-baseline rows stay
    # report-only, so the gate passes with only fig9_new_row regressed
    ok = _write(tmp_path / "ok.json", {
        "fig9_accl_udp_p8": {"us_per_call": 110.0, "derived": ""},
        "fig9_new_row": {"us_per_call": 20.0, "derived": ""},      # 1 baseline
        "e2e_rowpar_lat_winner_us": {"us_per_call": 45.0, "derived": ""},
        "topo_hops_sendrecv_h2_65536B": {"us_per_call": 35.0, "derived": ""},
    })
    assert bench_diff.main(["--old", b1, "--old", b2, "--new", ok]) == 0


def test_merge_baselines_lenient_reference():
    rows, counts = bench_diff.merge_baselines([
        {"a": {"us_per_call": 10.0}, "b": {"us_per_call": 5.0}},
        {"a": {"us_per_call": 14.0}},
    ])
    assert rows["a"]["us_per_call"] == 14.0   # most lenient
    assert counts == {"a": 2, "b": 1}


def test_split_enforced_tiers():
    regs = [("a", 10.0, 30.0, 3.0), ("b", 5.0, 20.0, 4.0),
            ("e2e_x", 1.0, 9.0, 9.0), ("topo_x", 1.0, 9.0, 9.0)]
    counts = {"a": 2, "b": 1, "e2e_x": 2, "topo_x": 2}
    hard, soft = bench_diff.split_enforced(
        regs, counts, n_baselines=2,
        report_only_prefixes=bench_diff.DEFAULT_REPORT_ONLY_PREFIXES)
    # e2e_ and topo_ rows are enforced now (>= 2 baselines, the default
    # report-only prefix list is empty); only single-baseline rows ride soft
    assert [r[0] for r in hard] == ["a", "e2e_x", "topo_x"]
    assert [r[0] for r in soft] == ["b"]
    # an explicit report-only prefix still works
    hard2, soft2 = bench_diff.split_enforced(
        regs, counts, n_baselines=2, report_only_prefixes=("topo_",))
    assert [r[0] for r in hard2] == ["a", "e2e_x"]
    assert sorted(r[0] for r in soft2) == ["b", "topo_x"]
    # single-baseline mode keeps the old semantics: everything enforced
    hard1, soft1 = bench_diff.split_enforced(
        regs, {"a": 1, "b": 1, "e2e_x": 1, "topo_x": 1}, 1, ())
    assert len(hard1) == 4 and not soft1


def test_main_bad_input(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    ok = _write(tmp_path / "ok.json", {})
    # malformed baseline: hard mode fails, report-only tolerates
    assert bench_diff.main(["--old", str(bad), "--new", ok]) == 2
    assert bench_diff.main(["--old", str(bad), "--new", ok,
                            "--report-only"]) == 0
    assert bench_diff.main(["--old", ok, "--new",
                            str(tmp_path / "nope.json")]) == 2
