"""Test helpers: subprocess isolation for multi-device tests.

The main pytest process must keep seeing ONE CPU device (smoke tests and
benches), so every test that needs a multi-device mesh launches a fresh
python subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def require_hypothesis():
    """Import hypothesis or skip — unless REPRO_REQUIRE_HYPOTHESIS is set
    (the CI pins the dep and sets the flag), in which case a missing install
    is a hard failure instead of a silent skip-and-pass."""
    import pytest
    try:
        import hypothesis
    except ImportError:
        if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
            raise
        pytest.skip("hypothesis not installed (set REPRO_REQUIRE_HYPOTHESIS "
                    "to make this a failure)")
    return hypothesis


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 540) -> str:
    """Run `code` in a subprocess with n host devices; returns stdout.

    Raises AssertionError with combined output on failure.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO))
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
